"""KV-cache quantization (paper Eq. 8): channel-wise b-bit integer quantization.

The paper stores preempted jobs' KV caches as INT8 and dequantizes back to the
compute dtype on resume.  We implement the standard asymmetric affine scheme

    x_q = round(x / lam + z),      x_hat = lam * (x_q - z)
    lam = (max - min) / (2^b - 1), z   = round(-min / lam)

(the paper's printed zero-point formula ``z = round(-2^b/(max-min))`` is
dimensionally a typo for the standard form above; noted in DESIGN.md).

Channel-wise: statistics are taken per channel (last axis by default), which
is what keeps attention quality acceptable for K tensors.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass
class QuantizedTensor:
    q: jnp.ndarray        # int8/int4-in-int8 codes
    scale: jnp.ndarray    # lam, broadcastable to x
    zero: jnp.ndarray     # z, same shape as scale
    bits: int

    @property
    def nbytes(self) -> int:
        return (self.q.size * self.bits) // 8 + self.scale.size * 4 + self.zero.size * 4


def quantize(x, bits: int = 8, axis: int = -1) -> QuantizedTensor:
    """Channel-wise asymmetric quantization along ``axis`` (kept per-channel)."""
    xf = x.astype(jnp.float32)
    reduce_axes = tuple(i for i in range(x.ndim) if i != (axis % x.ndim))
    mx = xf.max(axis=reduce_axes, keepdims=True)
    mn = xf.min(axis=reduce_axes, keepdims=True)
    qmax = 2.0 ** bits - 1.0
    lam = jnp.maximum((mx - mn) / qmax, 1e-8)
    z = jnp.round(-mn / lam)
    q = jnp.clip(jnp.round(xf / lam + z), 0, qmax)
    store_dtype = jnp.int8 if bits <= 8 else jnp.int32
    # int8 holds [0,255] as unsigned by offsetting into signed range
    q = (q - 128).astype(store_dtype) if bits == 8 else q.astype(store_dtype)
    return QuantizedTensor(q=q, scale=lam, zero=z, bits=bits)


def dequantize(qt: QuantizedTensor, dtype=jnp.bfloat16):
    q = qt.q.astype(jnp.float32)
    if qt.bits == 8:
        q = q + 128.0
    return (qt.scale * (q - qt.zero)).astype(dtype)


def quantize_np(x: np.ndarray, bits: int = 8, axis: int = -1):
    """Numpy twin used for host-side (DRAM tier) storage in the engine."""
    xf = x.astype(np.float32)
    reduce_axes = tuple(i for i in range(x.ndim) if i != (axis % x.ndim))
    mx = xf.max(axis=reduce_axes, keepdims=True)
    mn = xf.min(axis=reduce_axes, keepdims=True)
    qmax = 2.0 ** bits - 1.0
    lam = np.maximum((mx - mn) / qmax, 1e-8)
    z = np.round(-mn / lam)
    q = np.clip(np.round(xf / lam + z), 0, qmax)
    q8 = (q - 128).astype(np.int8) if bits == 8 else q.astype(np.int32)
    return q8, lam, z


def dequantize_np(q8: np.ndarray, lam: np.ndarray, z: np.ndarray,
                  bits: int = 8, dtype=np.float32) -> np.ndarray:
    q = q8.astype(np.float32)
    if bits == 8:
        q = q + 128.0
    return (lam * (q - z)).astype(dtype)


def roundtrip_rel_error(x, bits: int = 8, axis: int = -1) -> float:
    qt = quantize(x, bits=bits, axis=axis)
    xh = dequantize(qt, dtype=jnp.float32)
    num = jnp.abs(xh - x.astype(jnp.float32)).max()
    den = jnp.maximum(jnp.abs(x.astype(jnp.float32)).max(), 1e-9)
    return float(num / den)


def kv_bytes_per_token(num_layers: int, num_kv_heads: int, head_dim: int,
                       quantized: bool = False) -> int:
    """Bytes of KV per token: 2 (K,V) x layers x heads x dim x dtype bytes."""
    per = 2 * num_layers * num_kv_heads * head_dim
    return per * (1 if quantized else 2)   # int8 vs bf16
