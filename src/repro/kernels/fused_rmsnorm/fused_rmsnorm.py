"""Fused RMSNorm kernel (Pallas TPU) — the paper's fused LayerNorm analogue.

One pass per row tile: mean-of-squares reduction and the scaled multiply stay
in VMEM, avoiding the extra HBM round-trip of the unfused norm + mul pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = (x * x).mean(axis=-1, keepdims=True)
    o_ref[...] = (x * lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def fused_rmsnorm(x, scale, *, eps: float = 1e-5, blk: int = 256,
                  interpret: bool = False):
    """x: (T, d), scale: (d,) -> (T, d)."""
    T, d = x.shape
    blk = min(blk, T)
    assert T % blk == 0
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(T // blk,),
        in_specs=[pl.BlockSpec((blk, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=interpret,
    )(x, scale)
