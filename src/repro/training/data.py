"""Synthetic LM data pipeline: deterministic, shardable, restart-safe.

Batches are a pure function of (seed, step) so a restarted job resumes the
exact data order from its checkpoint step — the data-side half of
fault-tolerant training.  With a mesh, batches are placed sharded over the
(pod, data) axes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


@dataclass
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    # synthetic structure: orderless-markov bigram-ish stream so loss falls
    n_patterns: int = 97


class SyntheticLM:
    """Learnable synthetic stream: next token = f(prev token) + noise."""

    def __init__(self, cfg: ArchConfig, data_cfg: DataConfig):
        self.cfg = cfg
        self.dc = data_cfg
        rng = np.random.default_rng(data_cfg.seed)
        v = cfg.vocab_size
        self.succ = rng.integers(0, v, size=(v,), dtype=np.int64)

    def batch_at(self, step: int) -> dict:
        dc = self.dc
        rng = np.random.default_rng((dc.seed, step))
        B, S = dc.batch_size, dc.seq_len
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.cfg.vocab_size, B)
        noise = rng.random((B, S)) < 0.1
        rand = rng.integers(0, self.cfg.vocab_size, (B, S))
        for t in range(S):
            nxt = self.succ[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def iterate(self, start_step: int = 0,
                mesh: Optional[Mesh] = None) -> Iterator[dict]:
        step = start_step
        sharding = None
        if mesh is not None:
            axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            sharding = NamedSharding(mesh, P(axes if axes else None, None))
        while True:
            b = self.batch_at(step)
            if sharding is not None:
                b = {k: jax.device_put(v, sharding) for k, v in b.items()}
            else:
                b = {k: jnp.asarray(v) for k, v in b.items()}
            yield b
            step += 1
