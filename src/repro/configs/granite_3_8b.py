"""granite-3-8b — dense GQA decoder.

[hf:ibm-granite/granite-3.0-2b-base family; hf]
40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    norm_type="rmsnorm",
    act="swiglu",
    tie_embeddings=True,
)


def smoke_config() -> ArchConfig:
    return CONFIG.scaled(num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
                         d_ff=128, vocab_size=512)
