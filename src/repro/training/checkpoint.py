"""Sharded checkpointing with elastic resharding (fault tolerance).

Checkpoints store every leaf as a host array plus a manifest of tree paths,
dtypes and logical partition specs.  ``restore`` places leaves onto ANY mesh
(same or different size) by re-deriving shardings for the target mesh — this
is the elastic-scaling path: a 512-chip checkpoint restores onto 256 chips
(or one CPU device) unchanged.  Writes are atomic (tmp + rename) so a crash
mid-save never corrupts the latest checkpoint; ``latest_step`` enables
checkpoint/restart after node failure.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(ckpt_dir: str | Path, state, step: int) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(state)
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    manifest = {}
    arrays = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        safe = key.replace("/", "__")
        arrays[safe] = arr
        manifest[key] = {"dtype": str(arr.dtype), "shape": list(arr.shape)}
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(
        {"step": step, "leaves": manifest}))
    final = ckpt_dir / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                    # atomic publish
    (ckpt_dir / "LATEST").write_text(str(step))
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    marker = Path(ckpt_dir) / "LATEST"
    if not marker.exists():
        return None
    return int(marker.read_text().strip())


def restore_checkpoint(ckpt_dir: str | Path, state_template,
                       step: Optional[int] = None,
                       mesh: Optional[Mesh] = None,
                       spec_tree=None):
    """Restore onto `state_template`'s tree structure.

    With ``mesh``+``spec_tree`` the leaves are placed sharded (elastic:
    the target mesh need not match the mesh that wrote the checkpoint);
    otherwise they land on the default device.
    """
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    assert step is not None, f"no checkpoint in {ckpt_dir}"
    d = ckpt_dir / f"step_{step:08d}"
    data = np.load(d / "arrays.npz")
    flat_t, treedef = _flatten(state_template)
    spec_flat = None
    if spec_tree is not None:
        spec_flat, _ = _flatten(spec_tree)
    leaves = []
    for key, tmpl in flat_t.items():
        arr = data[key.replace("/", "__")]
        arr = arr.astype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr
        if mesh is not None and spec_flat is not None and key in spec_flat:
            arr = jax.device_put(arr, NamedSharding(mesh, spec_flat[key]))
        else:
            arr = jax.numpy.asarray(arr)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
