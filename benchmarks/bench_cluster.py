"""Beyond-paper: cluster-scale speculative routing + failure resilience."""
from __future__ import annotations

import time

from benchmarks.common import emit, note, pick
from repro.core.cluster import ClusterConfig, ClusterRouter
from repro.core.simulator import build_predictor
from repro.core.trace import TraceConfig, generate_trace


def run() -> dict:
    tc = TraceConfig(dataset="sharegpt", rate=pick(16.0, 4.0),
                     duration=pick(60.0, 8.0), seed=3)
    trace = generate_trace(tc)
    pred = build_predictor("retrieval", tc, pick(512, 64))
    n_rep = pick(4, 2)
    out = {}
    for router in ("round_robin", "join_shortest_queue", "ewt"):
        t0 = time.perf_counter()
        r = ClusterRouter(ClusterConfig(n_replicas=n_rep, router=router),
                          pred).run(trace)
        wall_us = (time.perf_counter() - t0) * 1e6
        out[router] = r.normalized_latency * 1e3
        emit(f"cluster/{router}/{n_rep}replicas", wall_us,
             f"norm_ms={out[router]:.2f};p99_s={r.p99_latency:.1f};"
             f"done={r.completed}/{r.total}")
    t0 = time.perf_counter()
    rf = ClusterRouter(ClusterConfig(n_replicas=n_rep, router="ewt",
                                     fail_at=pick(20.0, 3.0),
                                     recover_at=pick(40.0, 5.0)),
                       pred).run(trace)
    emit("cluster/ewt/failure_injection", (time.perf_counter() - t0) * 1e6,
         f"replayed={rf.replayed};done={rf.completed}/{rf.total};"
         f"norm_ms={rf.normalized_latency*1e3:.2f}")
    note(f"[cluster] ewt={out['ewt']:.1f}ms rr={out['round_robin']:.1f}ms | "
         f"failure: {rf.replayed} replayed, {rf.completed}/{rf.total} done")
    return out


if __name__ == "__main__":
    run()
