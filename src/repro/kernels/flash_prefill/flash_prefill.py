"""Causal GQA flash-attention prefill kernel (Pallas TPU).

Tiling: grid = (B*H, nq, nk); the kv axis is the innermost (sequential on
TPU), carrying an online-softmax (m, l, acc) state in VMEM scratch.  Block
shapes are MXU-aligned (q_blk x d and kv_blk x d tiles, d a multiple of 128
for full lanes).  Causal blocks above the diagonal are skipped with pl.when —
the kernel does ~half the FLOPs of the dense score matrix, which is the
hardware-adapted analogue of the paper's fused attention kernels (§3.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, q_blk: int, kv_blk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:   # skip blocks strictly above the diagonal
        run = (ki * kv_blk) <= (qi * q_blk + q_blk - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (q_blk, d)
        k = k_ref[0].astype(jnp.float32)            # (kv_blk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _prefix_kernel(start_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, heads: int, q_blk: int,
                   kv_blk: int, nk: int):
    """Chunk-over-prefix variant: queries are a C-token chunk whose
    absolute positions begin at ``start[b]`` while keys/values span the
    whole per-request stripe ``[0, Smax)``.  Same online-softmax state as
    :func:`_kernel`; the causal skip/mask use absolute positions, so the
    kernel reads ``O(C x (start + C))`` scores blockwise instead of
    materializing the dense ``C x Smax`` matrix."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    b = pl.program_id(0) // heads
    start = start_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip kv blocks entirely above this q block's last absolute position
    run = (ki * kv_blk) <= (start + qi * q_blk + q_blk - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (q_blk, d)
        k = k_ref[0].astype(jnp.float32)            # (kv_blk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = (start + qi * q_blk
                + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0))
        kpos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _largest_divisor(n: int, cap: int) -> int:
    for blk in range(min(cap, n), 0, -1):
        if n % blk == 0:
            return blk
    return n


def flash_prefill_prefix(q, k, v, start, *, q_blk: int = 128,
                         kv_blk: int = 128, interpret: bool = False):
    """Chunked-prefill attention over cached prefix KV.

    ``q``: (B, H, C, d) chunk queries; ``k``/``v``: (B, KVH, Smax, d)
    per-request stripes with positions ``[0, start[b] + C)`` materialized;
    ``start``: (B,) int32 absolute position of each chunk's first query.
    Returns (B, H, C, d).  Block sizes are clamped to divisors of C/Smax.
    """
    B, H, C, d = q.shape
    KVH, Smax = k.shape[1], k.shape[2]
    G = H // KVH
    q_blk = _largest_divisor(C, q_blk)
    kv_blk = _largest_divisor(Smax, kv_blk)
    nq, nk = C // q_blk, Smax // kv_blk
    scale = 1.0 / (d ** 0.5)

    qf = q.reshape(B * H, C, d)
    kf = k.reshape(B * KVH, Smax, d)
    vf = v.reshape(B * KVH, Smax, d)

    kernel = functools.partial(_prefix_kernel, scale=scale, heads=H,
                               q_blk=q_blk, kv_blk=kv_blk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # start (B,) int32
            pl.BlockSpec((1, q_blk, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kv_blk, d),
                         lambda bh, qi, ki: ((bh // G) if G > 1 else bh, ki, 0)),
            pl.BlockSpec((1, kv_blk, d),
                         lambda bh, qi, ki: ((bh // G) if G > 1 else bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, C, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk,), jnp.float32),       # running max
            pltpu.VMEM((q_blk,), jnp.float32),       # running sum
            pltpu.VMEM((q_blk, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(start.astype(jnp.int32), qf, kf, vf)
    return out.reshape(B, H, C, d)


def flash_prefill(q, k, v, *, causal: bool = True, q_blk: int = 256,
                  kv_blk: int = 256, interpret: bool = False):
    """q: (B, H, S, d); k/v: (B, KVH, S, d) -> (B, H, S, d)."""
    B, H, S, d = q.shape
    KVH = k.shape[1]
    G = H // KVH
    q_blk = min(q_blk, S)
    kv_blk = min(kv_blk, S)
    assert S % q_blk == 0 and S % kv_blk == 0
    nq, nk = S // q_blk, S // kv_blk
    scale = 1.0 / (d ** 0.5)

    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * KVH, S, d)
    vf = v.reshape(B * KVH, S, d)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               q_blk=q_blk, kv_blk=kv_blk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_blk, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kv_blk, d),
                         lambda bh, qi, ki: ((bh // G) if G > 1 else bh, ki, 0)),
            pl.BlockSpec((1, kv_blk, d),
                         lambda bh, qi, ki: ((bh // G) if G > 1 else bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk,), jnp.float32),       # running max
            pltpu.VMEM((q_blk,), jnp.float32),       # running sum
            pltpu.VMEM((q_blk, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, d)
