"""Online, hit-aware quantile length predictor.

Replaces the static per-request point prior on the serve path with a
hashed-feature quantile regressor (:class:`QuantileHeads`, p50/p90):

* **Hit-aware**: features condition on the prefix-cache/tier hit watermark
  and the SLO class (:mod:`.features`), so a multi-turn resend whose
  prefix is cached is priced as the short continuation it really is.
* **Online**: learns from completed-request feedback *and* censored
  in-flight feedback (overrun = "true length exceeds what we predicted"),
  applied off the dispatch hot path through the base class's bounded
  feedback queue (``observe``/``drain_feedback``).
* **Mid-flight re-prediction**: when generation crosses the current p50,
  :meth:`repredict` re-estimates the total from the class-conditional
  residual length distribution at a *decaying quantile level* (each
  successive overrun asks a more conservative quantile: 0.5, 0.75,
  0.875, ...), replacing blind doubling when enough history exists.
* **Calibrated uncertainty**: the p90 head carries an online
  conformal-style additive correction per SLO class — the adjustment
  integrates the coverage error (miss ⇒ widen, cover ⇒ shrink at 1/9 the
  rate) so empirical P90 coverage tracks nominal even when the regressor
  is conditionally misspecified.  Rolling pinball losses, coverage, and
  per-class MAE are exported as gauges.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.predictor import Feedback, LengthPredictor, Prediction
from repro.core.vector_db import VectorDB
from repro.serving.prediction.features import (TOKEN_DIM, LengthFeaturizer,
                                               knn_log_of)
from repro.serving.prediction.quantile import QuantileHeads, pinball_loss

_LOG_CAP = 9.2            # exp(9.2) ~ 9900 tokens: sane prediction ceiling


@dataclass
class OnlineConfig:
    quantiles: tuple = (0.5, 0.9)
    lr: float = 0.08
    init_len: float = 96.0              # cold-start prior (log-space bias)
    conformal_eta: float = 0.03         # coverage-correction integrator step
    coverage_window: int = 512          # rolling telemetry window
    residual_window: int = 512          # per-class observed-length ring
    min_residual_n: int = 8             # tail samples needed to repredict
    feedback_capacity: int = 4096
    drain_max: int = 64                 # feedback items applied per drain
    # retrieval prior (Algorithm 1's DB, repurposed as a *feature*): the
    # similarity-weighted KNN log-length estimate rides the context block
    # so the heads calibrate around it instead of re-deriving topic
    # structure from hashed n-grams alone
    knn_k: int = 8
    knn_threshold: float = 0.22
    db_capacity: int = 65536
    pretrain_epochs: int = 2
    seed: int = 0


class OnlineQuantilePredictor(LengthPredictor):
    name = "online"

    def __init__(self, cfg: Optional[OnlineConfig] = None, seed: int = 0):
        self.cfg = cfg or OnlineConfig(seed=seed)
        self.feedback_capacity = self.cfg.feedback_capacity
        self.feat = LengthFeaturizer(seed=self.cfg.seed)
        # the heads regress *residual* log-length quantiles around the
        # base prior (KNN estimate when the DB hits, cold-start constant
        # otherwise) — zero-initialized, so before any learning the p50
        # IS the retrieval estimate and quantiles calibrate around it
        self.heads = QuantileHeads(self.feat.dim, self.cfg.quantiles,
                                   lr=self.cfg.lr, init_log_len=0.0)
        self.db = VectorDB(self.feat.token_dim,
                           capacity=self.cfg.db_capacity, seed=self.cfg.seed)
        self._adj: Dict[str, float] = {}            # class -> log-space p90 adj
        self._cov: Dict[str, deque] = {}            # class -> 0/1 window
        self._mae: Dict[str, deque] = {}            # class -> |err| window
        self._pinball: Dict[float, deque] = {
            q: deque(maxlen=self.cfg.coverage_window)
            for q in self.cfg.quantiles}
        self._resid: Dict[str, deque] = {}          # class -> observed lengths
        self.last_latency = 0.0
        self.stats = {"predicts": 0, "repredicts": 0, "updates": 0,
                      "censored": 0}

    # ---------------------------------------------------------- prediction
    def _cls_of(self, slo_class) -> str:
        return getattr(slo_class, "value", str(slo_class or "batch"))

    def _featurize(self, tokens, prompt_len: int,
                   cached_prefix_hint: int = 0,
                   slo_class=None) -> np.ndarray:
        """Encode once, query the retrieval DB for the prior, build the
        full feature vector.  Token-less requests skip both (length-only
        path)."""
        if not tokens:
            return self.feat.features(None, prompt_len, cached_prefix_hint,
                                      slo_class)
        emb = self.feat.encoder.encode(tokens)
        knn_log = knn_conf = 0.0
        sims, lengths = self.db.search(emb, self.cfg.knn_k)
        est = self.db.predict_from_neighbors(sims, lengths,
                                             self.cfg.knn_threshold)
        if est is not None and est > 0:
            knn_log = float(np.log(max(est, 1.0)))
            knn_conf = float(np.max(sims))
        return self.feat.features(None, len(tokens), cached_prefix_hint,
                                  slo_class, token_emb=emb,
                                  knn_log=knn_log, knn_conf=knn_conf)

    def _base_log(self, x: np.ndarray) -> float:
        """Prior the residual heads calibrate around: the KNN estimate
        carried in the feature snapshot, or the cold-start constant."""
        b = knn_log_of(x)
        return b if b > 0.0 else float(np.log(self.cfg.init_len))

    def _quantiles_from(self, x: np.ndarray, cls: str):
        base = self._base_log(x)
        logs = base + self.heads.predict_log(x)
        p50 = int(round(float(np.exp(np.clip(logs[0], 0.0, _LOG_CAP)))))
        l90 = logs[-1] + self._adj.get(cls, 0.0)
        p90 = int(round(float(np.exp(np.clip(l90, 0.0, _LOG_CAP)))))
        p50 = max(p50, 1)
        return p50, max(p90, p50)

    def _predict_x(self, x: np.ndarray, cls: str, t0: float) -> Prediction:
        p50, p90 = self._quantiles_from(x, cls)
        lat = time.perf_counter() - t0
        self._note_latency(lat)
        self.last_latency = lat
        self.stats["predicts"] += 1
        return Prediction(length=p50, source="online", latency_s=lat,
                          p90=p90, spread=p90 / p50 - 1.0)

    def predict_for(self, req) -> Prediction:
        t0 = time.perf_counter()
        x = self._featurize(req.prompt_tokens, req.prompt_len,
                            req.cached_prefix_hint, req.slo_class)
        req.features = x        # snapshotted by observe(); reused on drain
        return self._predict_x(x, self._cls_of(req.slo_class), t0)

    def predict(self, tokens: Sequence[int],
                true_len: Optional[int] = None) -> Prediction:
        t0 = time.perf_counter()
        x = self._featurize(tokens, len(tokens) if tokens else 1)
        return self._predict_x(x, "batch", t0)

    def predict_length_only(self, prompt_len: int,
                            true_len: Optional[int] = None) -> Prediction:
        t0 = time.perf_counter()
        x = self._featurize(None, prompt_len)
        return self._predict_x(x, "batch", t0)

    # ----------------------------------------------- mid-flight re-predict
    def repredict(self, req) -> Optional[int]:
        """Decaying residual-quantile estimate once ``req`` crosses its
        current prediction: condition the class's observed-length
        distribution on survival past ``generated`` and read it at
        ``q_k = 1 - 0.5^(k+1)`` for the k-th overrun.  Falls back to None
        (caller doubles) until the residual ring holds enough tail mass."""
        cls = self._cls_of(req.slo_class)
        ring = self._resid.get(cls)
        g = req.generated
        if ring is None:
            return None
        tail = [v for v in ring if v > g]
        if len(tail) < self.cfg.min_residual_n:
            return None
        k = getattr(req, "repredictions", 0)
        q = 1.0 - 0.5 ** (k + 1)
        new_p50 = int(round(float(np.quantile(tail, q))))
        new_p90 = int(round(float(np.quantile(tail, max(q, 0.9)))))
        req.predicted_p90 = max(new_p90, new_p50)
        self.stats["repredicts"] += 1
        return max(new_p50, g + 1)

    # ------------------------------------------------------------ learning
    def _apply_feedback(self, item: Feedback) -> None:
        x = item.features
        if x is None:
            x = self._featurize(item.tokens, item.prompt_len,
                                item.cached_prefix_hint)
        cls = item.slo_class
        y = max(int(item.length), 1)
        y_log = float(np.log(y))
        if item.censored:
            self.stats["censored"] += 1
            # the conformal correction also sees censored misses: if the
            # current p90 already lies below the survived length, coverage
            # is definitionally violated regardless of the final total
            _, p90 = self._quantiles_from(x, cls)
            if y > p90:
                self._adj[cls] = self._adj.get(cls, 0.0) \
                    + self.cfg.conformal_eta * 0.9
            self.heads.update(x, y_log - self._base_log(x), censored=True)
            return
        p50, p90 = self._quantiles_from(x, cls)
        covered = y <= p90
        # integrate the coverage error toward the 0.9 target: a miss widens
        # by eta*0.9, a cover shrinks by eta*0.1 — zero drift at 90% hits
        self._adj[cls] = self._adj.get(cls, 0.0) + self.cfg.conformal_eta \
            * ((0.0 if covered else 1.0) - 0.1)
        win = self.cfg.coverage_window
        self._cov.setdefault(cls, deque(maxlen=win)).append(int(covered))
        self._mae.setdefault(cls, deque(maxlen=win)).append(abs(y - p50))
        for q, d in self._pinball.items():
            pred = p50 if q == 0.5 else p90
            d.append(pinball_loss(float(y), float(pred), q))
        self._resid.setdefault(
            cls, deque(maxlen=self.cfg.residual_window)).append(y)
        self.heads.update(x, y_log - self._base_log(x))
        emb = x[:TOKEN_DIM]
        if float(np.abs(emb).sum()) > 0.0:      # token block = the embedding
            self.db.add(np.array(emb, np.float32), float(y))
        self.stats["updates"] += 1

    def update(self, tokens: Sequence[int], true_len: int) -> None:
        """Synchronous interface-compat update (benchmarks/offline eval);
        the serve path goes through observe()/drain_feedback instead."""
        self._apply_feedback(Feedback(
            length=int(true_len),
            prompt_len=len(tokens) if tokens else 1,
            tokens=list(tokens) if tokens else None))

    def update_length_only(self, prompt_len: int, true_len: int) -> None:
        self._apply_feedback(Feedback(length=int(true_len),
                                      prompt_len=prompt_len))

    def drain_feedback(self, max_items: Optional[int] = None) -> int:
        return super().drain_feedback(max_items or self.cfg.drain_max)

    def pretrain(self, token_lists: List[Sequence[int]], lengths,
                 epochs: Optional[int] = None) -> None:
        """Warm start from a history corpus (same role as the retrieval
        predictor's DB warmup), **prequentially**: samples are shuffled and
        each one is featurized against the DB state its predecessors built
        before it is applied as feedback.  The residual targets the heads
        train on therefore come from the same base-prior dynamics serving
        produces — seeding the DB from a block prefix instead (e.g. one
        dataset of a mixed corpus) biases the pretrain-time base low/high
        and the heads bake the compensation in as pure serve-time bias.
        Extra epochs refine the heads on the snapshotted features."""
        lens = np.asarray(lengths, np.float32)
        if not len(lens):
            return
        idx = np.random.default_rng(self.cfg.seed).permutation(len(lens))
        feats: List[np.ndarray] = []
        order: List[int] = []
        for i in idx:
            t = token_lists[i]
            plen = len(t) if t else 1
            x = self._featurize(t, plen)
            self._apply_feedback(Feedback(length=int(lens[i]),
                                          prompt_len=plen, features=x))
            feats.append(x)
            order.append(int(i))
        extra = (epochs or self.cfg.pretrain_epochs) - 1
        if extra > 0:
            X = np.stack(feats)
            self.heads.fit(X, lens[order], epochs=extra,
                           seed=self.cfg.seed,
                           base_log=[self._base_log(x) for x in X])

    # ----------------------------------------------------------- telemetry
    def coverage(self, slo_class: str = "batch") -> Optional[float]:
        d = self._cov.get(slo_class)
        return (sum(d) / len(d)) if d else None

    def pinball(self, q: float) -> Optional[float]:
        d = self._pinball.get(q)
        return (float(np.mean(d)) if d else None)

    def mae(self, slo_class: str = "batch") -> Optional[float]:
        d = self._mae.get(slo_class)
        return (float(np.mean(d)) if d else None)

    def gauges(self) -> Dict[str, float]:
        g = super().gauges()
        for q in self.cfg.quantiles:
            v = self.pinball(q)
            if v is not None:
                g[f"predictor_pinball{int(q * 100)}"] = v
        for cls, d in self._cov.items():
            if d:
                g[f"predictor_cov90_{cls}"] = sum(d) / len(d)
        for cls, d in self._mae.items():
            if d:
                g[f"predictor_mae_{cls}"] = float(np.mean(d))
        g["predictor_repredicts"] = float(self.stats["repredicts"])
        g["predictor_updates"] = float(self.stats["updates"])
        return g
