"""Eq. 3-5 latency model fitting."""
import numpy as np
import pytest

from repro.core.latency_model import LatencyModel, calibrated


def test_fit_recovers_coefficients():
    true = LatencyModel(t0=1e-4, alpha=2e-6, beta=0.03)
    rng = np.random.default_rng(0)
    prefills = [(s, true.prefill_time(s) * (1 + 0.01 * rng.standard_normal()))
                for s in [64, 128, 256, 512, 1024, 2048]]
    decodes = [(s, true.decode_iter_time(s) * (1 + 0.01 * rng.standard_normal()))
               for s in [64, 128, 256, 512, 1024, 2048, 4096]]
    fit = LatencyModel.fit(prefills, decodes)
    assert fit.t0 == pytest.approx(true.t0, rel=0.05)
    assert fit.alpha == pytest.approx(true.alpha, rel=0.2)
    assert fit.beta == pytest.approx(true.beta, rel=0.05)
    assert fit.fit_error(prefills, decodes) < 0.05


def test_total_time_decomposition():
    m = LatencyModel(t0=1e-4, alpha=1e-6, beta=0.01)
    assert m.total_time(100, 50) == pytest.approx(
        m.prefill_time(100) + m.decode_time(100, 50))


def test_remaining_time_includes_prefill_when_cold():
    m = LatencyModel(t0=1e-4, alpha=1e-6, beta=0.01)
    cold = m.remaining_time(100, 0, 50, prefilled=False)
    warm = m.remaining_time(100, 0, 50, prefilled=True)
    assert cold - warm == pytest.approx(m.prefill_time(100))


def test_calibrated_scales_with_model_size():
    small, big = calibrated("opt-2.7b"), calibrated("opt-13b")
    assert big.beta > small.beta
    assert big.t0 > small.t0
