"""Grouped / padded-expert MoE variants: math consistency with the baseline.

These options exist for sharding performance (EXPERIMENTS.md §Perf B/C);
they must not change the model's semantics beyond capacity-drop boundaries.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import layers as L
from repro.models.model import Model


def _moe_cfg(E=4, K=2, cf=None):
    cfg = get_smoke_config("dbrx-132b")
    return dataclasses.replace(cfg, num_experts=E, top_k=K,
                               capacity_factor=cf if cf else float(E))


def test_grouped_equals_global_when_dropless():
    """With dropless capacity, grouping must not change the output."""
    cfg = _moe_cfg()
    rng = jax.random.PRNGKey(0)
    p = L.init_moe(cfg, rng, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y1, aux1 = L.apply_moe(cfg, p, x, groups=1)
    y2, aux2 = L.apply_moe(cfg, p, x, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    assert float(aux1) == pytest.approx(float(aux2), rel=1e-5)


def test_padded_experts_never_routed():
    """Dead (padded) expert slots must receive zero routing weight."""
    cfg = _moe_cfg(E=3, K=2)
    p = L.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32, pad_experts_to=8)
    assert p["wi"].shape[0] == 8
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    gate_w, gate_idx, _, E_alloc = L._route(cfg, p, x.reshape(-1, cfg.d_model))
    assert E_alloc == 8
    assert int(jnp.max(gate_idx)) < cfg.num_experts


def test_padded_equals_unpadded_math():
    """Padding the allocation must not change the routed computation."""
    cfg = _moe_cfg(E=4, K=2)
    rng = jax.random.PRNGKey(0)
    p = L.init_moe(cfg, rng, jnp.float32)
    p_pad = {
        "router": jnp.pad(p["router"], [(0, 0), (0, 4)], constant_values=-1e9),
        "wi": jnp.pad(p["wi"], [(0, 4), (0, 0), (0, 0)]),
        "wg": jnp.pad(p["wg"], [(0, 4), (0, 0), (0, 0)]),
        "wo": jnp.pad(p["wo"], [(0, 4), (0, 0), (0, 0)]),
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y1, _ = L.apply_moe(cfg, p, x)
    y2, _ = L.apply_moe(cfg, p_pad, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_grouped_model_trains_without_nans():
    cfg = get_smoke_config("granite-moe-3b-a800m")
    model = Model(cfg, attn_chunk=16, remat=False, moe_groups=2,
                  pad_experts_to=8)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "targets": jnp.zeros((2, 32), jnp.int32)}
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
