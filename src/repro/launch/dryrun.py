import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out runs/dryrun.jsonl] [--force]

Every cell ``.lower().compile()``s through XLA's SPMD partitioner with the
real production shardings; failures here are sharding bugs.  Results append
to a JSONL cache so the sweep is resumable.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, ASSIGNED_ARCHS, cell_is_supported, get_config
from repro.distributed.ctx import mesh_context
from repro.distributed.sharding import (batch_specs, cache_specs, param_specs,
                                        sanitize_specs, to_named)
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.training.train_step import make_train_step
from repro.training.optimizer import init_opt_state

# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s effective per link

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}


def _shape_bytes(type_str: str) -> int:
    """'bf16[128,1024]{1,0}' -> bytes."""
    m = re.match(r"([a-z]+[0-9]*)\[([0-9,]*)\]", type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def parse_collectives(hlo_text: str, scan_trip_counts: dict) -> dict:
    """Sum collective operand bytes from post-SPMD optimized HLO (per-device).

    Collectives inside while-loop (scan) bodies execute once per layer-loop
    trip; computations whose name marks them as scan/while bodies are scaled
    by the arch's trip count (the documented approximation in DESIGN.md).
    """
    per_kind = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    current_scale = 1
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("%") and ls.endswith("{") and "(" in ls:
            name = ls.split(" ", 1)[0]
            current_scale = 1
            for marker, trips in scan_trip_counts.items():
                if marker in name:
                    current_scale = trips
                    break
        for kind in _COLLECTIVES:
            token = f" {kind}("
            if token in ls or ls.startswith(f"{kind}("):
                # operand types appear inside the call parens
                args = ls.split(token, 1)[1]
                ops = re.findall(r"([a-z]+[0-9]*\[[0-9,]*\](?:\{[0-9,]*\})?)", args)
                nbytes = sum(_shape_bytes(o) for o in ops)
                if nbytes == 0:   # fall back to result type
                    m = re.search(r"([a-z]+[0-9]*\[[0-9,]*\])", ls.split("=", 1)[-1])
                    nbytes = _shape_bytes(m.group(1)) if m else 0
                per_kind[kind] += nbytes * current_scale
                counts[kind] += current_scale
                break
    total = sum(per_kind.values())
    return {"per_kind_bytes": per_kind, "counts": counts,
            "per_device_bytes": total}


def _model_for(arch: str, shape_name: str, opt: dict) -> Model:
    cfg = get_config(arch)
    # 1024-wide attention chunks keep the 32k cells' working set in check
    return Model(cfg, attn_impl="chunked",
                 attn_chunk=opt.get("attn_chunk", 1024),
                 ssd_chunk=256, remat=True,
                 kv_dtype=opt.get("kv_dtype", "bfloat16"),
                 moe_groups=opt.get("moe_groups", 1),
                 pad_experts_to=opt.get("pad_experts_to", 0),
                 ssm_state_dtype=opt.get("ssm_state_dtype", "float32"))


def lower_cell(arch: str, shape_name: str, mesh, *, opt: dict = None):
    """Build + lower + compile one cell; returns the result record."""
    opt = opt or {}
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = _model_for(arch, shape_name, opt)

    with mesh_context(mesh):
        params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        if shape.kind == "train":
            state_shape = jax.eval_shape(
                lambda p: {"params": p, **init_opt_state(p)}, params_shape)
            pspec = sanitize_specs(params_shape,
                                   param_specs(cfg, params_shape, "train"), mesh)
            mspec = sanitize_specs(state_shape["m"], pspec, mesh)
            state_spec = {"params": pspec, "m": mspec, "v": mspec,
                          "step": jax.sharding.PartitionSpec()}
            batch_shape = model.input_specs(shape)
            bspec = sanitize_specs(batch_shape,
                                   batch_specs(cfg, shape, mesh), mesh)
            step_fn = make_train_step(
                model, grad_compression=opt.get("grad_compression", False))
            jitted = jax.jit(step_fn,
                             in_shardings=(to_named(mesh, state_spec),
                                           to_named(mesh, bspec)),
                             out_shardings=(to_named(mesh, state_spec), None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shape, batch_shape)
        elif shape.kind == "prefill":
            pspec = sanitize_specs(params_shape,
                                   param_specs(cfg, params_shape, "serving"), mesh)
            batch_shape = model.input_specs(shape)
            bspec = sanitize_specs(batch_shape,
                                   batch_specs(cfg, shape, mesh), mesh)
            jitted = jax.jit(model.prefill,
                             in_shardings=(to_named(mesh, pspec),
                                           to_named(mesh, bspec)))
            lowered = jitted.lower(params_shape, batch_shape)
        else:  # decode
            pspec = sanitize_specs(params_shape,
                                   param_specs(cfg, params_shape, "serving"), mesh)
            ins = model.input_specs(shape)
            cspec = sanitize_specs(ins["cache"],
                                   cache_specs(cfg, shape, mesh), mesh)
            tspec = sanitize_specs(ins["tokens"],
                                   batch_specs(cfg, shape, mesh)["tokens"], mesh)
            jitted = jax.jit(model.decode_step,
                             in_shardings=(to_named(mesh, pspec),
                                           to_named(mesh, cspec),
                                           to_named(mesh, tspec)),
                             out_shardings=(None, to_named(mesh, cspec)),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shape, ins["cache"], ins["tokens"])

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # scan trip counts for collective scaling
    trips = {"body": _layer_trips(cfg)}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, trips)
    n_chips = mesh.devices.size

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": int(n_chips),
        "compile_s": round(compile_s, 2),
        "opt": opt,
        "memory": {
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev},
        "collectives": coll,
        "roofline": _roofline(cfg, SHAPES[shape_name], flops_dev, bytes_dev,
                              coll["per_device_bytes"], n_chips),
        "hlo_bytes": len(hlo),
    }
    return record


def _layer_trips(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    return cfg.num_layers


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for train, 2*N_active*D for a forward-only cell."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens


def _roofline(cfg, shape, flops_dev, bytes_dev, coll_dev, chips):
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    hlo_total = flops_dev * chips
    return {
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": (mf / hlo_total) if hlo_total else None,
        "bound_s": max(t_compute, t_memory, t_coll),
    }


def run_cells(archs, shapes, meshes, out_path: Path, force: bool = False,
              opt: dict = None):
    opt = opt or {}
    opt_key = json.dumps(opt, sort_keys=True)
    done = set()
    if out_path.exists() and not force:
        for line in out_path.read_text().splitlines():
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"],
                          json.dumps(r.get("opt") or {}, sort_keys=True)))
            except Exception:
                pass
    out_path.parent.mkdir(parents=True, exist_ok=True)
    mesh_objs = {}
    if "single" in meshes:
        mesh_objs["16x16"] = make_production_mesh(multi_pod=False)
    if "multi" in meshes:
        mesh_objs["2x16x16"] = make_production_mesh(multi_pod=True)

    with out_path.open("a") as fh:
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                ok, reason = cell_is_supported(cfg, SHAPES[shape_name])
                for mesh_name, mesh in mesh_objs.items():
                    key = (arch, shape_name, mesh_name, opt_key)
                    if key in done:
                        print(f"[skip-cached] {key}")
                        continue
                    if not ok:
                        rec = {"arch": arch, "shape": shape_name,
                               "mesh": mesh_name, "skipped": True,
                               "reason": reason, "opt": opt}
                        fh.write(json.dumps(rec) + "\n")
                        fh.flush()
                        print(f"[skip] {arch} {shape_name}: {reason}")
                        continue
                    print(f"[lower] {arch} {shape_name} {mesh_name} opt={opt} ...",
                          flush=True)
                    t0 = time.time()
                    try:
                        rec = lower_cell(arch, shape_name, mesh, opt=opt)
                        rec["wall_s"] = round(time.time() - t0, 1)
                        print(f"  ok in {rec['wall_s']}s compile={rec['compile_s']}s "
                              f"dominant={rec['roofline']['dominant']}", flush=True)
                    except Exception as e:
                        rec = {"arch": arch, "shape": shape_name,
                               "mesh": mesh_name, "error": str(e)[:2000],
                               "traceback": traceback.format_exc()[-4000:],
                               "opt": opt, "wall_s": round(time.time() - t0, 1)}
                        print(f"  FAILED: {e}", flush=True)
                    fh.write(json.dumps(rec) + "\n")
                    fh.flush()


def _parse_opt(s: str) -> dict:
    out = {}
    if not s:
        return out
    for kv in s.split(","):
        k, v = kv.split("=")
        try:
            out[k] = int(v)
        except ValueError:
            out[k] = v if v not in ("true", "false") else (v == "true")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="runs/dryrun.jsonl")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", default="", help="k=v,... perf-variant options "
                    "(kv_dtype, moe_groups, pad_experts_to, attn_chunk)")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    run_cells(archs, shapes, meshes, Path(args.out), force=args.force,
              opt=_parse_opt(args.opt))


if __name__ == "__main__":
    main()
