"""Online, hit-aware quantile length prediction (serve-path subsystem).

See :mod:`.online` for the predictor, :mod:`.features` for the hit-aware
feature extraction, :mod:`.quantile` for the pinball-loss heads.
"""
from repro.serving.prediction.features import (CTX_DIM, FEATURE_DIM,
                                               TOKEN_DIM, LengthFeaturizer)
from repro.serving.prediction.online import (OnlineConfig,
                                             OnlineQuantilePredictor)
from repro.serving.prediction.quantile import QuantileHeads, pinball_loss

__all__ = ["OnlineQuantilePredictor", "OnlineConfig", "LengthFeaturizer",
           "QuantileHeads", "pinball_loss", "FEATURE_DIM", "TOKEN_DIM",
           "CTX_DIM"]
