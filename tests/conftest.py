"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device; the
512-device setting belongs exclusively to launch/dryrun.py."""
import jax
import numpy as np
import pytest

from repro.core.request import Request, reset_request_counter


@pytest.fixture(autouse=True)
def _fresh_request_ids():
    reset_request_counter()
    yield


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_request(prompt_len=8, arrival=0.0, out_len=10, seed=0, vocab=512):
    r = np.random.default_rng(seed)
    return Request(prompt_len=prompt_len, arrival_time=arrival,
                   true_out_len=out_len,
                   prompt_tokens=r.integers(2, vocab, prompt_len).tolist())
