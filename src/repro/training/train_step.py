"""Train/serve step builders shared by the launcher, smoke tests and dry-run."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax

from repro.distributed.collectives import compress_grads_int8
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(model: Model, opt_cfg: Optional[AdamWConfig] = None,
                    grad_compression: bool = False):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    state = {"params", "m", "v", "step"}.  Gradients reduce over the data/pod
    axes implicitly through pjit; optional INT8 compression (error feedback
    lives in the optimizer moments' normal accumulation) is applied to the
    gradient tree before the optimizer when ``grad_compression``.
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(state, batch):
        def loss_fn(params):
            loss, metrics = model.loss(params, batch)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        if grad_compression:
            grads = compress_grads_int8(grads)
        opt_state = {"m": state["m"], "v": state["v"], "step": state["step"]}
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state["params"], grads, opt_state)
        new_state = {"params": new_params, **new_opt}
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return new_state, metrics

    return train_step


def init_train_state(model: Model, rng) -> Dict[str, Any]:
    params = model.init(rng)
    return {"params": params, **init_opt_state(params)}


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return decode_step
