"""Per-SLO-class serving telemetry.

TTFT  = first_token_time - arrival_time        (queueing + prefill)
TPOT  = (finish - first_token) / (n_tokens-1)  (steady-state decode pace)
E2E   = finish - arrival

All times are in the gateway's clock domain (wall seconds in realtime mode,
virtual seconds in replay mode), so percentiles are comparable across both.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.request import Request, SLOClass


def percentile(xs: List[float], p: float) -> float:
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, dtype=float), p))


@dataclass
class ClassMetrics:
    ttft: List[float] = field(default_factory=list)
    tpot: List[float] = field(default_factory=list)
    e2e: List[float] = field(default_factory=list)
    tokens: int = 0
    completed: int = 0
    cancelled: int = 0
    shed: int = 0
    deferred: int = 0          # admission defer decisions (not unique reqs)
    timed_out: int = 0         # aborted before first token (wall budget)
    ttft_target: Optional[float] = None   # SLO target (s); None = untracked

    def record_first_token(self, req: Request, t: float) -> None:
        self.ttft.append(t - req.arrival_time)

    def record_finish(self, req: Request, t: float) -> None:
        self.completed += 1
        self.tokens += req.generated
        self.e2e.append(t - req.arrival_time)
        if req.first_token_time is not None and req.generated > 1:
            self.tpot.append((t - req.first_token_time)
                             / (req.generated - 1))

    def slo_attainment(self) -> float:
        """Fraction of *arrivals* whose TTFT met the target; sheds and
        pre-first-token aborts count as misses, so neither shedding nor
        timing out can game the SLO."""
        if self.ttft_target is None:
            return float("nan")
        n = len(self.ttft) + self.shed + self.timed_out
        if n == 0:
            return float("nan")
        met = sum(1 for t in self.ttft if t <= self.ttft_target)
        return met / n

    def summary(self) -> Dict[str, float]:
        return {
            "completed": self.completed, "shed": self.shed,
            "cancelled": self.cancelled, "deferred": self.deferred,
            "timed_out": self.timed_out, "tokens": self.tokens,
            "ttft_p50": percentile(self.ttft, 50),
            "ttft_p90": percentile(self.ttft, 90),
            "ttft_p99": percentile(self.ttft, 99),
            "tpot_p50": percentile(self.tpot, 50),
            "tpot_p99": percentile(self.tpot, 99),
            "e2e_p50": percentile(self.e2e, 50),
            "e2e_p99": percentile(self.e2e, 99),
            "ttft_target": (float("nan") if self.ttft_target is None
                            else self.ttft_target),
            "slo_attainment": self.slo_attainment(),
        }


class GatewayMetrics:
    """Aggregates per-class stats; shared by the gateway and benchmarks."""

    def __init__(self):
        self.per_class: Dict[SLOClass, ClassMetrics] = {
            c: ClassMetrics() for c in SLOClass}
        self.start_t: float = 0.0
        self.end_t: float = 0.0

    def of(self, req: Request) -> ClassMetrics:
        return self.per_class[req.slo_class]

    def set_ttft_target(self, slo_class: SLOClass,
                        target: Optional[float]) -> None:
        self.per_class[slo_class].ttft_target = target

    @property
    def duration(self) -> float:
        return max(self.end_t - self.start_t, 1e-9)

    def completed(self) -> int:
        return sum(m.completed for m in self.per_class.values())

    def goodput(self) -> float:
        """Completed requests per second of serving time."""
        return self.completed() / self.duration

    def token_throughput(self) -> float:
        return sum(m.tokens for m in self.per_class.values()) / self.duration

    def summary(self, bus=None) -> Dict[str, object]:
        """Per-class metrics; with an observability ``bus`` attached the
        summary gains ``quality`` (scheduler-quality telemetry derived
        from the event stream) and ``gauges`` (the latest occupancy
        snapshot per replica) blocks."""
        out: Dict[str, object] = {
            "duration_s": self.duration,
            "goodput_rps": self.goodput(),
            "tok_per_s": self.token_throughput(),
        }
        for c, m in self.per_class.items():
            out[c.value] = m.summary()
        if bus is not None:
            from repro.serving.observability import analyze_quality
            out["quality"] = analyze_quality(bus)
            latest: Dict[str, Dict[str, float]] = {}
            for ev in bus.snapshot():
                if ev.kind == "gauge":
                    latest.setdefault(ev.replica, {}).update(
                        {k: v for k, v in ev.data.items()
                         if isinstance(v, (int, float))})
            out["gauges"] = latest
        return out

    def format_line(self, now: Optional[float] = None) -> str:
        """One-line heartbeat: aggregate progress + per-class TTFT p50
        so far (for ``--metrics-interval`` periodic printing).  ``now``
        supplies the in-flight duration (end_t is not yet set mid-serve)."""
        dur = max((self.end_t if now is None else now) - self.start_t, 1e-9)
        toks = sum(m.tokens for m in self.per_class.values())
        parts = [f"done={self.completed()}", f"{toks / dur:.1f} tok/s"]
        for c, m in self.per_class.items():
            if m.ttft:
                parts.append(f"{c.value[:5]}: n={len(m.ttft)} "
                             f"ttft_p50={percentile(m.ttft, 50):.3f}s")
            extra = m.shed + m.timed_out
            if extra:
                parts.append(f"{c.value[:5]}_lost={extra}")
        return "  ".join(parts)

    def format(self) -> str:
        lines = [f"duration {self.duration:.2f}s  "
                 f"goodput {self.goodput():.2f} req/s  "
                 f"{self.token_throughput():.1f} tok/s"]
        for c, m in self.per_class.items():
            s = m.summary()
            slo = ""
            if m.ttft_target is not None:
                slo = (f" SLO(ttft<={m.ttft_target:.2f}s)="
                       f"{s['slo_attainment']*100:.1f}%")
            lines.append(
                f"  {c.value:>11}: done={s['completed']:<4d} "
                f"shed={s['shed']:<3d} "
                f"TTFT p50/p99={s['ttft_p50']:.3f}/{s['ttft_p99']:.3f}s "
                f"TPOT p50={s['tpot_p50']*1e3:.1f}ms "
                f"E2E p50/p99={s['e2e_p50']:.3f}/{s['e2e_p99']:.3f}s"
                + slo)
        return "\n".join(lines)
