"""The paper's own evaluation models (OPT / LLaMA / Pythia families).

Used by the ALISE serving simulator + benchmarks (Figs. 2/6/8/9, Tables 2/3)
and by the real-engine examples at reduced scale.  Public configs:
OPT [arXiv:2205.01068], LLaMA [arXiv:2302.13971], Pythia [arXiv:2304.01373].
"""
from repro.models.config import ArchConfig

CONFIGS = {
    # ALISE Table 1
    "opt-2.7b": ArchConfig("opt-2.7b", "dense", 32, 2560, 32, 32, 10240, 50272,
                           norm_type="layernorm", act="relu", qkv_bias=True,
                           tie_embeddings=True),
    "opt-6.7b": ArchConfig("opt-6.7b", "dense", 32, 4096, 32, 32, 16384, 50272,
                           norm_type="layernorm", act="relu", qkv_bias=True,
                           tie_embeddings=True),
    "opt-13b": ArchConfig("opt-13b", "dense", 40, 5120, 40, 40, 20480, 50272,
                          norm_type="layernorm", act="relu", qkv_bias=True,
                          tie_embeddings=True),
    # ALISE Table 3
    "llama-7b": ArchConfig("llama-7b", "dense", 32, 4096, 32, 32, 11008, 32000,
                           norm_type="rmsnorm", act="swiglu"),
    "llama-13b": ArchConfig("llama-13b", "dense", 40, 5120, 40, 40, 13824, 32000,
                            norm_type="rmsnorm", act="swiglu"),
    "pythia-12b": ArchConfig("pythia-12b", "dense", 36, 5120, 40, 40, 20480, 50688,
                             norm_type="layernorm", act="gelu", qkv_bias=True),
}
