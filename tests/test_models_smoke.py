"""Per-arch reduced-config smoke tests: one forward/train step on CPU,
shape + finiteness asserts (assignment requirement f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.models.config import SHAPES, cell_is_supported
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def _batch_for(cfg, B=2, S=32):
    batch = {"targets": jnp.zeros((B, S), jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jnp.full((B, S, cfg.d_model), 0.1, jnp.float32)
        batch["tokens"] = jnp.ones((B, S), jnp.int32)
    elif cfg.input_mode == "embeds":
        batch["embeds"] = jnp.full((B, S, cfg.d_model), 0.1, jnp.float32)
    else:
        batch["tokens"] = jnp.ones((B, S), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg, attn_chunk=16, ssd_chunk=8, remat=False)
    state = init_train_state(model, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss)
    # a sane CE for a 512-vocab random model
    assert 2.0 < float(metrics["ce"]) < 12.0
    # one more step must not NaN
    state, metrics2 = step(state, batch)
    assert np.isfinite(float(metrics2["loss"]))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg, attn_chunk=16, ssd_chunk=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {}
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jnp.full((B, 24, cfg.d_model), 0.1, jnp.float32)
        batch["tokens"] = jnp.ones((B, S), jnp.int32)
    elif cfg.input_mode == "embeds":
        batch["embeds"] = jnp.full((B, S, cfg.d_model), 0.1, jnp.float32)
    else:
        batch["tokens"] = jnp.ones((B, S), jnp.int32)
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    if "k" in cache:
        pads = [(0, 0)] * cache["k"].ndim
        pads[2] = (0, 8)
        cache = {k: (jnp.pad(v, pads) if k in ("k", "v") else v)
                 for k, v in cache.items()}
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert np.all(np.asarray(cache2["lengths"])
                  == np.asarray(cache["lengths"]) + 1)


def test_full_configs_match_assignment():
    """The full-size configs carry the exact published dimensions."""
    spec = {
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "mamba2-2.7b": (64, 2560, None, None, 0, 50280),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }
    for arch, (L, D, H, KVH, F, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L and cfg.d_model == D
        assert cfg.d_ff == F and cfg.vocab_size == V
        if H is not None:
            assert cfg.num_heads == H and cfg.num_kv_heads == KVH
    assert get_config("dbrx-132b").num_experts == 16
    assert get_config("dbrx-132b").top_k == 4
    assert get_config("granite-moe-3b-a800m").num_experts == 40
    assert get_config("granite-moe-3b-a800m").top_k == 8
    assert get_config("jamba-1.5-large-398b").num_experts == 16
    assert get_config("jamba-1.5-large-398b").top_k == 2
    assert get_config("mamba2-2.7b").ssm_state == 128


def test_param_counts_plausible():
    expect = {"command-r-35b": (28e9, 40e9), "dbrx-132b": (120e9, 140e9),
              "jamba-1.5-large-398b": (350e9, 430e9),
              "qwen1.5-32b": (28e9, 38e9), "granite-3-8b": (7e9, 10e9),
              "mamba2-2.7b": (2.2e9, 3.2e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"


def test_jamba_interleave_pattern():
    cfg = get_config("jamba-1.5-large-398b")
    kinds = [cfg.layer_kind(i) for i in range(8)]
    assert kinds == ["ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm", "ssm"]
    assert sum(k == "attn" for k in (cfg.layer_kind(i) for i in range(72))) == 9
    ffns = [cfg.ffn_kind(i) for i in range(4)]
    assert ffns == ["dense", "moe", "dense", "moe"]


def test_long_500k_skips_match_spec():
    runnable = [a for a in ASSIGNED_ARCHS
                if cell_is_supported(get_config(a), SHAPES["long_500k"])[0]]
    assert sorted(runnable) == ["jamba-1.5-large-398b", "mamba2-2.7b"]
