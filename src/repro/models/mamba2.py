"""Mamba-2 (SSD — state-space duality) block in pure JAX.

Implements the chunked SSD algorithm [arXiv:2405.21060] for train/prefill and
the O(1) recurrent step for decode.  ngroups=1 (B/C shared across heads).

Shapes:  x (B,S,H,P), dt (B,S,H), A (H,), Bmat/Cmat (B,S,N).
State:   ssm (B,H,P,N) float32, conv (B,W-1,di+2N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ArchConfig
from repro.models.layers import _dense_init, apply_norm, init_norm


# ------------------------------------------------------------------ SSD core

def _segsum(dA):
    """dA: (..., Q) -> (..., Q, Q) lower-triangular segment sums.

    out[..., i, j] = sum_{j < t <= i} dA[..., t]   (−inf above diagonal).
    """
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, Bmat, Cmat, *, chunk: int, initial_state=None):
    """Chunked SSD scan.  Returns (y, final_state).

    x: (B,S,H,P) values; dt: (B,S,H) positive step sizes; A: (H,) negative;
    Bmat/Cmat: (B,S,N).  final_state: (B,H,P,N) float32.
    """
    Bsz, S, H, P = x.shape
    N = Bmat.shape[-1]
    if S % chunk:   # largest divisor of S that is <= chunk (exactness > speed)
        chunk = next(c for c in range(min(chunk, S), 0, -1) if S % c == 0)
    C = S // chunk

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = dtf * A.astype(jnp.float32)                      # (B,S,H)
    xbar = xf * dtf[..., None]                            # fold dt into x

    # chunked views
    xc = xbar.reshape(Bsz, C, chunk, H, P)
    dAc = dA.reshape(Bsz, C, chunk, H)
    Bc = Bmat.astype(jnp.float32).reshape(Bsz, C, chunk, N)
    Cc = Cmat.astype(jnp.float32).reshape(Bsz, C, chunk, N)

    cumA = jnp.cumsum(dAc, axis=2)                        # (B,C,Q,H)

    # 1) intra-chunk (quadratic within chunk, like windowed attention)
    L = jnp.exp(_segsum(dAc.transpose(0, 1, 3, 2)))       # (B,C,H,Q,Q)
    y_diag = jnp.einsum("bcqn,bcsn,bchqs,bcshp->bcqhp", Cc, Bc, L, xc)

    # 2) per-chunk final states
    decay_states = jnp.exp(cumA[:, :, -1:, :] - cumA)     # (B,C,Q,H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, decay_states, xc)

    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(cumA[:, :, -1, :])              # (B,C,H)
    s0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((Bsz, H, P, N), jnp.float32))

    def step(S_prev, inp):
        lam, st = inp                                     # (B,H), (B,H,P,N)
        S_new = S_prev * lam[..., None, None] + st
        return S_new, S_prev                              # emit pre-chunk state

    lam_c = chunk_decay.transpose(1, 0, 2)                # (C,B,H)
    st_c = states.transpose(1, 0, 2, 3, 4)                # (C,B,H,P,N)
    final_state, prev_states = lax.scan(step, s0, (lam_c, st_c))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,C,H,P,N)

    # 4) inter-chunk contribution to outputs
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, prev_states, jnp.exp(cumA))

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x, dt, A, Bmat, Cmat):
    """One recurrent step.  x:(B,H,P) dt:(B,H) Bmat/Cmat:(B,N) state:(B,H,P,N)."""
    dA = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))     # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(jnp.float32),
                     Bmat.astype(jnp.float32), x.astype(jnp.float32))
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cmat.astype(jnp.float32), new_state)
    return y.astype(x.dtype), new_state


# -------------------------------------------------------------- Mamba2 block

def init_mamba_block(cfg: ArchConfig, rng, dtype):
    D, di, N, H, W = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.conv_width)
    ks = jax.random.split(rng, 4)
    conv_ch = di + 2 * N
    return {
        "in_proj": _dense_init(ks[0], (D, 2 * di + 2 * N + H), dtype=dtype),
        "conv_w": (_dense_init(ks[1], (W, conv_ch), scale=0.5, dtype=dtype)),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),            # A = -exp(A_log) = -1
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": init_norm(cfg, di, dtype),
        "out_proj": _dense_init(ks[3], (di, D), dtype=dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _causal_conv(xBC, w, b, initial=None):
    """Depthwise causal conv.  xBC:(B,S,Ch), w:(W,Ch).  initial:(B,W-1,Ch)."""
    W = w.shape[0]
    pad = (initial if initial is not None
           else jnp.zeros((xBC.shape[0], W - 1, xBC.shape[-1]), xBC.dtype))
    xp = jnp.concatenate([pad, xBC], axis=1)              # (B, S+W-1, Ch)
    out = sum(xp[:, i:i + xBC.shape[1], :] * w[i][None, None, :] for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else pad[:, :0]
    return jax.nn.silu(out + b), new_state


def mamba_block(cfg: ArchConfig, p, x, *, chunk: int = 256,
                initial=None, return_state: bool = False):
    """Full-sequence Mamba-2 mixer.  x: (B,S,D) -> (B,S,D)."""
    Bsz, S, D = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    conv_init = initial["conv"] if initial is not None else None
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_init)
    xs = xBC[..., :di].reshape(Bsz, S, H, P)
    Bmat = xBC[..., di:di + N]
    Cmat = xBC[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    ssm_init = initial["ssm"] if initial is not None else None
    y, ssm_state = ssd_chunked(xs, dt, A, Bmat, Cmat, chunk=min(chunk, S),
                               initial_state=ssm_init)
    y = y + xs * p["D_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(Bsz, S, di)
    y = apply_norm(cfg, p["gate_norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"]
    if return_state:
        return out, {"conv": conv_state, "ssm": ssm_state}
    return out


def mamba_decode_step(cfg: ArchConfig, p, x, state):
    """One-token decode.  x: (B,D); state: {conv:(B,W-1,Ch), ssm:(B,H,P,N)}."""
    Bsz, D = x.shape
    di, N, H, P, W = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                      cfg.ssm_headdim, cfg.conv_width)
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # conv: shift register
    conv = state["conv"]
    window = jnp.concatenate([conv, xBC[:, None, :]], axis=1)     # (B,W,Ch)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     p["conv_w"].astype(jnp.float32))
    xBC = jax.nn.silu(out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    new_conv = window[:, 1:, :]
    xs = xBC[..., :di].reshape(Bsz, H, P)
    Bmat = xBC[..., di:di + N]
    Cmat = xBC[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, new_ssm = ssd_decode_step(state["ssm"], xs, dt, A, Bmat, Cmat)
    y = y + xs * p["D_skip"][None, :, None].astype(y.dtype)
    y = y.reshape(Bsz, di)
    y = apply_norm(cfg, p["gate_norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"], {"conv": new_conv, "ssm": new_ssm}


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    di, N, H, P, W = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                      cfg.ssm_headdim, cfg.conv_width)
    return {"conv": jnp.zeros((batch, W - 1, di + 2 * N), dtype),
            "ssm": jnp.zeros((batch, H, P, N), jnp.float32)}
