"""ALISE paper core: speculative scheduling + adaptive KV memory management."""
