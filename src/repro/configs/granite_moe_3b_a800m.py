"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf]
32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8
(config line of record; the hf card's 32e/top-8 variant noted in DESIGN.md).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    top_k=8,
    norm_type="rmsnorm",
    act="swiglu",
    tie_embeddings=True,
)


def smoke_config() -> ArchConfig:
    return CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                         d_ff=64, vocab_size=512, num_experts=4, top_k=2)
