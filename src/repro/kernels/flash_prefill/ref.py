"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(q, k, v, *, causal: bool = True):
    """q: (B, H, S, d); k/v: (B, KVH, S, d); returns (B, H, S, d)."""
    B, H, S, d = q.shape
    KVH = k.shape[1]
    G = H // KVH
    qg = q.reshape(B, KVH, G, S, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg, kf) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", w, vf)
    return o.reshape(B, H, S, d).astype(q.dtype)


def flash_prefill_prefix_ref(q, k, v, start):
    """q: (B, H, C, d); k/v: (B, KVH, Smax, d); start: (B,) int32.
    Chunk queries at absolute positions ``start[b] + i`` attend stripe
    keys ``j <= start[b] + i``; returns (B, H, C, d)."""
    B, H, C, d = q.shape
    KVH, Smax = k.shape[1], k.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, C, d).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg, k.astype(jnp.float32)) / (d ** 0.5)
    qpos = start[:, None] + jnp.arange(C)[None]                  # (B, C)
    mask = jnp.arange(Smax)[None, None] <= qpos[:, :, None]      # (B, C, Smax)
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", w, v.astype(jnp.float32))
    return o.reshape(B, H, C, d).astype(q.dtype)
