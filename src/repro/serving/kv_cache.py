"""Paged KV cache pool (vLLM-style block manager) wired to the Pallas
paged-attention kernels.

This is the block-granular allocator the vLLM baseline uses and the substrate
ALISE's request-level swapping sits on: pages for a request can be freed,
offloaded (optionally INT8), and re-materialized without moving other
requests' pages.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np


@dataclass
class PagedKVConfig:
    num_pages: int = 256
    page_size: int = 16
    num_kv_heads: int = 8
    head_dim: int = 64
    num_layers: int = 4
    dtype: str = "float32"


class PagedKVPool:
    """Physical page pool + per-request page tables (one layer set each)."""

    def __init__(self, cfg: PagedKVConfig):
        self.cfg = cfg
        shape = (cfg.num_layers, cfg.num_pages, cfg.page_size,
                 cfg.num_kv_heads, cfg.head_dim)
        self.k = jnp.zeros(shape, cfg.dtype)
        self.v = jnp.zeros(shape, cfg.dtype)
        self.free_pages: List[int] = list(range(cfg.num_pages))
        self.page_table: Dict[int, List[int]] = {}       # req -> pages
        self.lengths: Dict[int, int] = {}

    # ------------------------------------------------------------ allocator
    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.cfg.page_size)

    def can_allocate(self, tokens: int) -> bool:
        return len(self.free_pages) >= self.pages_needed(tokens)

    def allocate(self, req_id: int, tokens: int) -> List[int]:
        n = self.pages_needed(tokens)
        assert len(self.free_pages) >= n, "page pool exhausted"
        pages = [self.free_pages.pop() for _ in range(n)]
        self.page_table[req_id] = pages
        self.lengths[req_id] = tokens
        return pages

    def extend(self, req_id: int, new_tokens: int = 1) -> Optional[int]:
        """Grow a sequence; returns a newly-allocated page id or None."""
        length = self.lengths[req_id] + new_tokens
        need = self.pages_needed(length)
        new_page = None
        if need > len(self.page_table[req_id]):
            assert self.free_pages, "page pool exhausted"
            new_page = self.free_pages.pop()
            self.page_table[req_id].append(new_page)
        self.lengths[req_id] = length
        return new_page

    def free(self, req_id: int) -> None:
        self.free_pages.extend(self.page_table.pop(req_id, []))
        self.lengths.pop(req_id, None)

    def utilization(self) -> float:
        return 1.0 - len(self.free_pages) / self.cfg.num_pages

    # ------------------------------------------------------------- KV write
    def write_tokens(self, req_id: int, layer: int, pos: int, k_new, v_new):
        """Write one token's KV at logical position pos.  k_new: (KVH, d)."""
        pages = self.page_table[req_id]
        page = pages[pos // self.cfg.page_size]
        off = pos % self.cfg.page_size
        self.k = self.k.at[layer, page, off].set(k_new.astype(self.k.dtype))
        self.v = self.v.at[layer, page, off].set(v_new.astype(self.v.dtype))

    def block_table_array(self, req_ids: List[int]) -> tuple:
        """(tables (B, max_pages) int32, lengths (B,) int32) padded."""
        max_pages = max((len(self.page_table[r]) for r in req_ids), default=1)
        tables = np.zeros((len(req_ids), max_pages), np.int32)
        lens = np.zeros((len(req_ids),), np.int32)
        for i, r in enumerate(req_ids):
            pages = self.page_table[r]
            tables[i, :len(pages)] = pages
            lens[i] = self.lengths[r]
        return jnp.asarray(tables), jnp.asarray(lens)

    # ----------------------------------------------------------- swap paths
    def snapshot(self, req_id: int) -> dict:
        """Copy a request's pages to host (offload unit)."""
        pages = self.page_table[req_id]
        idx = jnp.asarray(pages)
        return {"k": np.asarray(self.k[:, idx]),
                "v": np.asarray(self.v[:, idx]),
                "tokens": self.lengths[req_id]}

    def restore(self, req_id: int, snap: dict) -> None:
        pages = self.allocate(req_id, snap["tokens"])
        idx = jnp.asarray(pages)
        self.k = self.k.at[:, idx].set(jnp.asarray(snap["k"]))
        self.v = self.v.at[:, idx].set(jnp.asarray(snap["v"]))
